//! The timlint rule engine: a hand-rolled lexer + token-stream analysis
//! over `rust/src/**` (the offline toolchain has no `syn`, so the linter
//! lexes Rust itself — comments, strings, raw strings, char literals and
//! lifetimes are handled; everything else is idents/numbers/puncts).
//!
//! This file is deliberately self-contained (std only, no `use` of the
//! binary's modules) so it can be compiled twice: as `mod lint` of the
//! `timlint` binary, and via `#[path]` into the root crate's
//! `timlint_rules` integration test — tier-1 `cargo test` exercises every
//! rule without building the tool.
//!
//! Rules (see DESIGN.md "Static verification layer"):
//!
//! | rule              | scope                        | what it bans              |
//! |-------------------|------------------------------|---------------------------|
//! | `hot-path-alloc`  | `#[timdnn::hot_path]` fns    | heap-allocating calls     |
//! | `narrowing-cast`  | `#[timdnn::hot_path]` fns    | `as` to i8..u32           |
//! | `rng-construction`| everywhere except util/prng  | RNG state built directly  |
//! | `digitize-f32`    | `impl Digitize for` bodies   | any f32/f64 arithmetic    |
//! | `vmm-mode-match`  | every `match` on `VmmMode`   | missing variant/wildcard  |
//! | `mutex-lock-unwrap`| `rust/src/**`               | bare `.lock().unwrap()`   |
//! | `no-float-in-intsoftmax` | `transformer/intmath.rs` | any float token, file-wide |
//! | `no-println-outside-report` | `rust/src/**` minus report/CLI paths | `println!`/`eprintln!` |
//!
//! Waivers: a `// timlint::allow(rule): why` comment covers its own line
//! and the next; `#[timdnn::timlint_allow(rule)]` covers a whole fn.

/// One lint finding, ready to print as `file:line: [rule] message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

pub const RULE_HOT_ALLOC: &str = "hot-path-alloc";
pub const RULE_NARROWING: &str = "narrowing-cast";
pub const RULE_RNG: &str = "rng-construction";
pub const RULE_DIGITIZE_F32: &str = "digitize-f32";
pub const RULE_VMM_MATCH: &str = "vmm-mode-match";
pub const RULE_MUTEX: &str = "mutex-lock-unwrap";
pub const RULE_INTSOFTMAX_FLOAT: &str = "no-float-in-intsoftmax";
pub const RULE_PRINTLN: &str = "no-println-outside-report";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Ident,
    Num,
    Punct,
}

#[derive(Clone, Copy, Debug)]
struct Tok<'a> {
    text: &'a str,
    line: usize,
    kind: Kind,
}

/// A `// timlint::allow(...)` comment marker.
struct Allow {
    line: usize,
    rules: Vec<String>,
}

// ---------------------------------------------------------------- lexer

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_' || b >= 0x80
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// Skip a (non-raw) string/char body starting *after* the opening quote;
/// returns the index one past the closing `quote`.
fn skip_quoted(b: &[u8], mut i: usize, quote: u8, line: &mut usize) -> usize {
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            c if c == quote => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// If `i` starts a raw (byte) string (`r"`, `r#"`, `br"`, …), return
/// `(end_index, newlines_spanned)` past the closing delimiter. Pure —
/// safe to call both as a branch guard and for its result.
fn raw_string_end(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    let mut newlines = 0;
    while j < b.len() {
        if b[j] == b'\n' {
            newlines += 1;
            j += 1;
        } else if b[j] == b'"'
            && b[j + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes
        {
            return Some((j + 1 + hashes, newlines));
        } else {
            j += 1;
        }
    }
    Some((j, newlines))
}

/// Parse a `timlint::allow(rule[, rule…])` marker out of a line comment.
fn parse_allow_marker(comment: &str) -> Option<Vec<String>> {
    let at = comment.find("timlint::allow(")?;
    let rest = &comment[at + "timlint::allow(".len()..];
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        None
    } else {
        Some(rules)
    }
}

fn tokenize(src: &str) -> (Vec<Tok<'_>>, Vec<Allow>) {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut allows = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            if let Some(rules) = parse_allow_marker(&src[start..i]) {
                allows.push(Allow { line, rules });
            }
        } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == b'"' {
            i = skip_quoted(b, i + 1, b'"', &mut line);
        } else if (c == b'r' || c == b'b') && raw_string_end(b, i).is_some() {
            let (end, newlines) = raw_string_end(b, i).unwrap_or((i + 1, 0));
            i = end;
            line += newlines;
        } else if c == b'b' && i + 1 < b.len() && b[i + 1] == b'"' {
            i = skip_quoted(b, i + 2, b'"', &mut line);
        } else if c == b'b' && i + 1 < b.len() && b[i + 1] == b'\'' {
            i = skip_quoted(b, i + 2, b'\'', &mut line);
        } else if c == b'\'' {
            // Lifetime vs char literal: `'ident` not followed by a closing
            // quote is a lifetime; anything else is a char literal.
            if i + 1 < b.len()
                && is_ident_start(b[i + 1])
                && !(i + 2 < b.len() && b[i + 2] == b'\'')
            {
                i += 1;
                while i < b.len() && is_ident_cont(b[i]) {
                    i += 1;
                }
            } else {
                i = skip_quoted(b, i + 1, b'\'', &mut line);
            }
        } else if is_ident_start(c) {
            let start = i;
            i += 1;
            while i < b.len() && is_ident_cont(b[i]) {
                i += 1;
            }
            toks.push(Tok { text: &src[start..i], line, kind: Kind::Ident });
        } else if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            toks.push(Tok { text: &src[start..i], line, kind: Kind::Num });
        } else {
            toks.push(Tok { text: &src[i..i + 1], line, kind: Kind::Punct });
            i += 1;
        }
    }
    (toks, allows)
}

// ------------------------------------------------------------- structure

/// Index one past the bracket matching `toks[open]` (same bracket kind).
fn match_bracket(toks: &[Tok], open: usize, o: &str, c: &str) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < toks.len() {
        if toks[j].text == o {
            depth += 1;
        } else if toks[j].text == c {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    toks.len() - 1
}

struct FnRegion {
    /// Token range of the body (exclusive of the braces).
    body: (usize, usize),
    hot: bool,
    allows: Vec<String>,
}

struct DigRegion {
    body: (usize, usize),
}

/// Scan items: functions (with their `#[timdnn::hot_path]` /
/// `#[timdnn::timlint_allow]` attributes) and `impl Digitize for` blocks.
/// Scanning continues *inside* bodies, so associated fns and nested items
/// are covered.
fn scan_items(toks: &[Tok]) -> (Vec<FnRegion>, Vec<DigRegion>) {
    let mut fns = Vec::new();
    let mut digs = Vec::new();
    let mut pending_hot = false;
    let mut pending_allows: Vec<String> = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        let t = toks[i].text;
        if t == "#" {
            let mut j = i + 1;
            if j < toks.len() && toks[j].text == "!" {
                j += 1;
            }
            if j < toks.len() && toks[j].text == "[" {
                let end = match_bracket(toks, j, "[", "]");
                let attr = &toks[j + 1..end];
                // Path idents before the argument parens.
                let mut path_last = "";
                let mut args_at = attr.len();
                for (k, a) in attr.iter().enumerate() {
                    if a.text == "(" {
                        args_at = k;
                        break;
                    }
                    if a.kind == Kind::Ident {
                        path_last = a.text;
                    }
                }
                if path_last == "hot_path" {
                    pending_hot = true;
                } else if path_last == "timlint_allow" && args_at < attr.len() {
                    let arg_end = match_bracket(attr, args_at, "(", ")");
                    for rule in attr[args_at + 1..arg_end].split(|a| a.text == ",") {
                        let name: String = rule.iter().map(|a| a.text).collect();
                        if !name.is_empty() {
                            pending_allows.push(name);
                        }
                    }
                }
                i = end + 1;
                continue;
            }
            i += 1;
        } else if t == "fn" && toks.get(i + 1).is_some_and(|n| n.kind == Kind::Ident) {
            // Find the body `{` (or a trailing `;` for a bodiless decl) at
            // paren/bracket depth 0.
            let mut j = i + 2;
            let mut depth = 0i32;
            let mut body_start = None;
            while j < toks.len() {
                match toks[j].text {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        body_start = Some(j);
                        break;
                    }
                    ";" if depth == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if let Some(bs) = body_start {
                let be = match_bracket(toks, bs, "{", "}");
                fns.push(FnRegion {
                    body: (bs + 1, be),
                    hot: pending_hot,
                    allows: std::mem::take(&mut pending_allows),
                });
                pending_hot = false;
                i = bs + 1; // keep scanning inside the body
            } else {
                pending_hot = false;
                pending_allows.clear();
                i = j + 1;
            }
        } else if t == "impl" {
            let mut j = i + 1;
            let mut depth = 0i32;
            let mut body_start = None;
            while j < toks.len() {
                match toks[j].text {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        body_start = Some(j);
                        break;
                    }
                    ";" if depth == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if let Some(bs) = body_start {
                let header = &toks[i + 1..bs];
                if header.iter().any(|a| a.text == "Digitize")
                    && header.iter().any(|a| a.text == "for")
                {
                    let be = match_bracket(toks, bs, "{", "}");
                    digs.push(DigRegion { body: (bs + 1, be) });
                }
                pending_hot = false;
                pending_allows.clear();
                i = bs + 1; // scan associated items
            } else {
                i = j + 1;
            }
        } else {
            i += 1;
        }
    }
    (fns, digs)
}

// ----------------------------------------------------------------- rules

const ALLOC_TYPES: [&str; 7] = ["Vec", "String", "Box", "Rc", "Arc", "VecDeque", "BTreeMap"];
const ALLOC_CTORS: [&str; 3] = ["new", "with_capacity", "from"];
const ALLOC_MACROS: [&str; 2] = ["vec", "format"];
const ALLOC_METHODS: [&str; 6] = ["push", "collect", "to_vec", "clone", "to_string", "to_owned"];
const NARROW_TARGETS: [&str; 6] = ["i8", "i16", "i32", "u8", "u16", "u32"];
const RNG_FREE_FNS: [&str; 6] =
    ["thread_rng", "from_entropy", "getrandom", "OsRng", "StdRng", "SmallRng"];
const RNG_STATE_TYPES: [&str; 2] = ["Rng", "SplitMix64"];
const VMM_VARIANTS: [&str; 3] = ["Ideal", "Analog", "AnalogNoisy"];

struct Ctx<'a> {
    file: &'a str,
    toks: Vec<Tok<'a>>,
    allows: Vec<Allow>,
    fns: Vec<FnRegion>,
    findings: Vec<Finding>,
}

impl Ctx<'_> {
    /// Is `rule` waived at `line` (same-line or preceding-line comment
    /// marker) or by the enclosing fn's `timlint_allow` attribute?
    fn allowed(&self, tok_idx: usize, rule: &str) -> bool {
        let line = self.toks[tok_idx].line;
        if self
            .allows
            .iter()
            .any(|a| (a.line == line || a.line + 1 == line) && a.rules.iter().any(|r| r == rule))
        {
            return true;
        }
        self.fns.iter().any(|f| {
            f.body.0 <= tok_idx && tok_idx < f.body.1 && f.allows.iter().any(|r| r == rule)
        })
    }

    fn report(&mut self, tok_idx: usize, rule: &'static str, message: String) {
        if !self.allowed(tok_idx, rule) {
            self.findings.push(Finding {
                file: self.file.to_string(),
                line: self.toks[tok_idx].line,
                rule,
                message,
            });
        }
    }

    fn text(&self, idx: usize) -> &str {
        self.toks.get(idx).map_or("", |t| t.text)
    }

    fn hot_path_rules(&mut self, body: (usize, usize)) {
        let (start, end) = body;
        for j in start..end {
            let t = self.toks[j];
            if t.kind == Kind::Ident {
                if ALLOC_TYPES.contains(&t.text)
                    && self.text(j + 1) == ":"
                    && self.text(j + 2) == ":"
                    && ALLOC_CTORS.contains(&self.text(j + 3))
                {
                    let msg = format!(
                        "`{}::{}` allocates inside a #[timdnn::hot_path] fn",
                        t.text,
                        self.text(j + 3)
                    );
                    self.report(j, RULE_HOT_ALLOC, msg);
                } else if ALLOC_MACROS.contains(&t.text) && self.text(j + 1) == "!" {
                    let msg =
                        format!("`{}!` allocates inside a #[timdnn::hot_path] fn", t.text);
                    self.report(j, RULE_HOT_ALLOC, msg);
                } else if t.text == "as" && NARROW_TARGETS.contains(&self.text(j + 1)) {
                    let msg = format!(
                        "`as {}` narrowing cast in a #[timdnn::hot_path] accumulator path; \
                         use try_from or justify with timlint::allow",
                        self.text(j + 1)
                    );
                    self.report(j, RULE_NARROWING, msg);
                }
            } else if t.text == "."
                && self.toks.get(j + 1).is_some_and(|n| n.kind == Kind::Ident)
                && ALLOC_METHODS.contains(&self.text(j + 1))
                && self.text(j + 2) == "("
            {
                let msg = format!(
                    "`.{}(` allocates inside a #[timdnn::hot_path] fn",
                    self.text(j + 1)
                );
                self.report(j + 1, RULE_HOT_ALLOC, msg);
            }
        }
    }

    /// Shared float-token detector: an `f32`/`f64` ident, a suffixed
    /// numeric literal, or a `1.5`-style float literal (Num '.' Num).
    fn float_tok(&self, j: usize) -> bool {
        let t = self.toks[j];
        match t.kind {
            Kind::Ident => t.text == "f32" || t.text == "f64",
            Kind::Num => {
                t.text.ends_with("f32")
                    || t.text.ends_with("f64")
                    || (self.text(j + 1) == "."
                        && self.toks.get(j + 2).is_some_and(|n| n.kind == Kind::Num))
            }
            Kind::Punct => false,
        }
    }

    fn digitize_rules(&mut self, body: (usize, usize)) {
        let (start, end) = body;
        for j in start..end {
            if self.float_tok(j) {
                let msg = format!(
                    "float arithmetic (`{}`) inside a Digitize impl — digitization must stay \
                     integer until the caller's single scale conversion",
                    self.toks[j].text
                );
                self.report(j, RULE_DIGITIZE_F32, msg);
            }
        }
    }

    /// `no-float-in-intsoftmax`: inside the integer softmax/layernorm
    /// module every token of the file — test modules included — is under
    /// the same float detector that guards `Digitize` impls. The decode
    /// loop's bit-reproducibility depends on this span staying pure
    /// fixed-point; the float boundary lives in `transformer/mod.rs` and
    /// the serving tensor conversion, never here.
    fn intsoftmax_rules(&mut self) {
        for j in 0..self.toks.len() {
            if self.float_tok(j) {
                let msg = format!(
                    "float token (`{}`) in the integer softmax/layernorm module — \
                     transformer/intmath.rs is fixed-point only, file-wide; move float \
                     code (oracles, conversions) to the caller or the test crate",
                    self.toks[j].text
                );
                self.report(j, RULE_INTSOFTMAX_FLOAT, msg);
            }
        }
    }

    fn rng_rules(&mut self) {
        for j in 0..self.toks.len() {
            let t = self.toks[j];
            if t.kind != Kind::Ident {
                continue;
            }
            if t.text == "rand" && self.text(j + 1) == ":" && self.text(j + 2) == ":" {
                self.report(
                    j,
                    RULE_RNG,
                    "`rand::` usage outside util::prng — all randomness flows through \
                     util::prng::Rng for reproducibility"
                        .to_string(),
                );
            } else if RNG_FREE_FNS.contains(&t.text) {
                // Path-qualified occurrences (`rand::thread_rng`,
                // `StdRng::from_entropy`) are already reported at the path
                // root; flag only the free-standing ident.
                if j == 0 || self.text(j - 1) != ":" {
                    let msg = format!(
                        "`{}` constructs RNG state outside util::prng; seed a util::prng::Rng \
                         instead",
                        t.text
                    );
                    self.report(j, RULE_RNG, msg);
                }
            } else if RNG_STATE_TYPES.contains(&t.text) && self.text(j + 1) == "{" {
                let prev = if j == 0 { "" } else { self.text(j - 1) };
                // Skip definition/return-type positions.
                if !matches!(
                    prev,
                    "impl" | "for" | "mod" | "struct" | "enum" | "trait" | "union" | ">" | ":"
                        | "dyn" | "as"
                ) {
                    let msg = format!(
                        "direct `{} {{ … }}` state construction outside util::prng; use \
                         Rng::seeded",
                        t.text
                    );
                    self.report(j, RULE_RNG, msg);
                }
            }
        }
    }

    /// A bare `.lock().unwrap()` turns a poisoned mutex (some thread
    /// panicked while holding it) into a cascading crash. The rule applies
    /// to all of `rust/src/**`: supervised workers may panic anywhere a
    /// backend runs, so every subsystem that shares a mutex with them must
    /// use `coordinator::lock_unpoisoned` — or explicit `PoisonError`
    /// handling such as `unwrap_or_else(PoisonError::into_inner)` — to
    /// keep serving through worker panics. Waivable where a panic is the
    /// intended behaviour (e.g. tests that poison a mutex on purpose).
    fn mutex_rules(&mut self) {
        for j in 0..self.toks.len() {
            if self.toks[j].text == "."
                && self.text(j + 1) == "lock"
                && self.text(j + 2) == "("
                && self.text(j + 3) == ")"
                && self.text(j + 4) == "."
                && self.text(j + 5) == "unwrap"
                && self.text(j + 6) == "("
            {
                self.report(
                    j + 1,
                    RULE_MUTEX,
                    "bare `.lock().unwrap()` panics on a poisoned mutex; use \
                     coordinator::lock_unpoisoned (or handle the PoisonError) \
                     so a worker panic cannot cascade"
                        .to_string(),
                );
            }
        }
    }

    /// `no-println-outside-report`: ad-hoc stdout/stderr writes from
    /// library code bypass the typed observability surface — the worker
    /// loop's state changes belong in the engine event ring
    /// (`telemetry::EventRing`) and aggregates in `MetricsSnapshot`, not
    /// interleaved on stderr where no consumer can see them. The
    /// sanctioned report/CLI paths (where printing *is* the product) are
    /// carved out in [`is_report_module`]; anywhere else, waive with
    /// `timlint::allow` and a reason.
    fn println_rules(&mut self) {
        for j in 0..self.toks.len() {
            let t = self.toks[j];
            if t.kind == Kind::Ident
                && (t.text == "println" || t.text == "eprintln")
                && self.text(j + 1) == "!"
            {
                let msg = format!(
                    "`{}!` outside the sanctioned report/CLI paths; push a typed \
                     EngineEvent (telemetry::EventRing) or extend MetricsSnapshot instead",
                    t.text
                );
                self.report(j, RULE_PRINTLN, msg);
            }
        }
    }

    fn vmm_match_rules(&mut self) {
        let mut j = 0;
        while j < self.toks.len() {
            if !(self.toks[j].kind == Kind::Ident && self.toks[j].text == "match") {
                j += 1;
                continue;
            }
            // Scrutinee: first `{` at paren/bracket depth 0 opens the body
            // (struct literals in scrutinee position require parens).
            let mut k = j + 1;
            let mut depth = 0i32;
            let mut body_start = None;
            while k < self.toks.len() {
                match self.toks[k].text {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        body_start = Some(k);
                        break;
                    }
                    ";" if depth == 0 => break,
                    _ => {}
                }
                k += 1;
            }
            let Some(bs) = body_start else {
                j += 1;
                continue;
            };
            let be = match_bracket(&self.toks, bs, "{", "}");
            self.check_match_arms(j, bs, be);
            j = bs + 1; // nested matches get their own pass
        }
    }

    /// Segment `toks[bs+1..be]` into arm patterns and, if any pattern
    /// names a `VmmMode::` variant, require all variants and no wildcard.
    fn check_match_arms(&mut self, match_idx: usize, bs: usize, be: usize) {
        let mut patterns: Vec<(usize, usize)> = Vec::new();
        let mut p_start = bs + 1;
        let mut depth = 0i32;
        let mut k = bs + 1;
        while k < be {
            match self.toks[k].text {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                "=" if depth == 0 && self.text(k + 1) == ">" => {
                    patterns.push((p_start, k));
                    // Skip the arm body.
                    k += 2;
                    if k < be && self.toks[k].text == "{" {
                        k = match_bracket(&self.toks, k, "{", "}") + 1;
                        if k < be && self.toks[k].text == "," {
                            k += 1;
                        }
                    } else {
                        let mut d2 = 0i32;
                        while k < be {
                            match self.toks[k].text {
                                "(" | "[" | "{" => d2 += 1,
                                ")" | "]" | "}" => d2 -= 1,
                                "," if d2 == 0 => {
                                    k += 1;
                                    break;
                                }
                                _ => {}
                            }
                            k += 1;
                        }
                    }
                    p_start = k;
                    continue;
                }
                _ => {}
            }
            k += 1;
        }
        let mut seen: Vec<&str> = Vec::new();
        let mut catchall = false;
        let mut mentions_vmm = false;
        for &(ps, pe) in &patterns {
            let pat = &self.toks[ps..pe];
            // Guards are expression territory — only inspect up to `if`.
            let pat_end = pat
                .iter()
                .position(|t| t.kind == Kind::Ident && t.text == "if")
                .unwrap_or(pat.len());
            let pat = &pat[..pat_end];
            for (x, t) in pat.iter().enumerate() {
                if t.text == "VmmMode"
                    && x + 3 < pat.len() + 1
                    && pat.get(x + 1).is_some_and(|a| a.text == ":")
                    && pat.get(x + 2).is_some_and(|a| a.text == ":")
                {
                    mentions_vmm = true;
                    if let Some(v) = pat.get(x + 3) {
                        if !seen.contains(&v.text) {
                            seen.push(v.text);
                        }
                    }
                }
            }
            // `_ => …` or a lone binding `other => …` swallows variants.
            if pat.len() == 1 && (pat[0].text == "_" || pat[0].kind == Kind::Ident) {
                catchall = true;
            }
            if pat.first().is_some_and(|t| t.text == "_") {
                catchall = true;
            }
        }
        if !mentions_vmm {
            return;
        }
        let missing: Vec<&str> =
            VMM_VARIANTS.iter().filter(|v| !seen.contains(v)).copied().collect();
        if catchall || !missing.is_empty() {
            let msg = format!(
                "match on VmmMode must name every variant (Ideal | Analog | AnalogNoisy) with \
                 no catch-all arm; missing: {missing:?}, catch-all: {catchall} — a new mode \
                 must be handled everywhere, not silently defaulted"
            );
            self.report(match_idx, RULE_VMM_MATCH, msg);
        }
    }
}

/// True when `file` is the sanctioned RNG module (the one place RNG state
/// may be constructed).
fn is_prng_module(file: &str) -> bool {
    file.replace('\\', "/").ends_with("util/prng.rs")
}

/// True when `file` is the integer softmax/layernorm module, whose whole
/// token stream is under the `no-float-in-intsoftmax` ban.
fn is_intsoftmax_module(file: &str) -> bool {
    file.replace('\\', "/").ends_with("transformer/intmath.rs")
}

/// True when `file` is a sanctioned human-facing report/CLI path —
/// direct stdout writes are the product there, so
/// `no-println-outside-report` does not apply: the CLI entry point, the
/// metrics `report()` printer, and the table/bench render helpers.
fn is_report_module(file: &str) -> bool {
    let f = file.replace('\\', "/");
    f == "main.rs"
        || f.ends_with("/main.rs")
        || ["coordinator/metrics.rs", "util/cli.rs", "util/table.rs", "util/bench.rs"]
            .iter()
            .any(|suffix| f.ends_with(suffix))
}

/// Lint one source file; `file` is used for diagnostics and the
/// `util/prng.rs` carve-out.
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    let (toks, allows) = tokenize(src);
    let (fns, digs) = scan_items(&toks);
    let hot_bodies: Vec<(usize, usize)> = fns.iter().filter(|f| f.hot).map(|f| f.body).collect();
    let dig_bodies: Vec<(usize, usize)> = digs.iter().map(|d| d.body).collect();
    let mut ctx = Ctx { file, toks, allows, fns, findings: Vec::new() };
    for body in hot_bodies {
        ctx.hot_path_rules(body);
    }
    for body in dig_bodies {
        ctx.digitize_rules(body);
    }
    if !is_prng_module(file) {
        ctx.rng_rules();
    }
    if is_intsoftmax_module(file) {
        ctx.intsoftmax_rules();
    }
    if !is_report_module(file) {
        ctx.println_rules();
    }
    ctx.mutex_rules();
    ctx.vmm_match_rules();
    ctx.findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    ctx.findings
}
