//! Inert marker attributes for the `timlint` static analyzer.
//!
//! Both attributes return their item unchanged — they carry no runtime
//! semantics. Their value is entirely static: `tools/timlint` keys its
//! source-level rules off them, and a reviewer can see at the definition
//! site which contract a function is under.

#![forbid(unsafe_code)]

use proc_macro::TokenStream;

/// Marks a function as a steady-state hot path. `timlint` then forbids
/// heap-allocating calls (`Vec::new`, `push`, `collect`, `to_vec`,
/// `clone`, `format!`, …) and `as` narrowing casts inside its body;
/// deviations need a `// timlint::allow(rule): why` line marker or a
/// [`macro@timlint_allow`] attribute.
#[proc_macro_attribute]
pub fn hot_path(_attr: TokenStream, item: TokenStream) -> TokenStream {
    item
}

/// Item-level lint waiver: `#[timdnn::timlint_allow(narrowing-cast)]`
/// suppresses the named `timlint` rule for the whole item. Prefer the
/// line-granular `// timlint::allow(rule): why` comment marker; use the
/// attribute when every occurrence in the item shares one justification
/// (state it in a doc comment or regular comment at the site).
#[proc_macro_attribute]
pub fn timlint_allow(_attr: TokenStream, item: TokenStream) -> TokenStream {
    item
}
